import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This module is the ONLY place the 512-device
# placeholder platform is created; smoke tests and benches see 1 device.

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Any, Dict, Optional, Tuple  # noqa: E402

import numpy as np       # noqa: E402
import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                                    # noqa: E402
from repro.models.api import Model, batch_partition_specs, input_specs  # noqa: E402
from repro.models.config import LM_SHAPES, is_subquadratic, shape_cell  # noqa: E402
from repro.parallel import sharding as sh                     # noqa: E402
from repro.topology import hlocost                             # noqa: E402
from repro.train import optimizer as opt_lib                  # noqa: E402
from repro.train.step import (make_decode_step, make_prefill_step,  # noqa: E402
                              make_train_step)
from repro.launch.mesh import activate_mesh, make_production_mesh  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            out[k] = {"true": True, "false": False}.get(v.lower(), v)
    return out


def _sharded_bytes_per_device(tree, spec_tree, mesh) -> int:
    """Analytic per-device bytes of a sharded pytree (exact for weights)."""
    total = 0
    specs = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
    leaves = jax.tree.leaves(tree)
    for leaf, spec in zip(leaves, specs):
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shards *= mesh.shape[a]
        total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize // max(shards, 1)
    return total


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: Optional[Dict[str, Any]] = None,
               rule_overrides: Optional[Dict[str, Any]] = None,
               microbatch: int = 1) -> Dict[str, Any]:
    """Lower + compile one (arch x shape x mesh) cell; return the record."""
    cfg = configs.get_config(arch)
    if overrides:
        cfg = cfg.with_overrides(**overrides)
    cell = shape_cell(shape_name)

    if cell.name == "long_500k" and not is_subquadratic(cfg):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "pure full-attention arch; long_500k requires "
                          "sub-quadratic attention (DESIGN.md S5)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = int(np.prod(list(mesh.shape.values())))
    rules = sh.rules_for_mesh(mesh, rule_overrides)
    dp_size = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    if cell.global_batch % dp_size != 0:
        # long_500k has global_batch=1: batch cannot shard over the data
        # axes; activations/caches replicate on batch and shard on seq/tp.
        rules = dict(rules)
        rules["batch"] = None
    model = Model(cfg)
    t0 = time.time()

    with sh.use_rules(rules), activate_mesh(mesh):
        decls = model.decls()
        aparams = model.abstract()
        pspecs = sh.resolve_tree(model.specs(), rules)
        psh = _named(mesh, pspecs)
        batch_sds = input_specs(cfg, cell)
        bspecs = sh.resolve_tree(batch_partition_specs(cfg, cell), rules)
        bsh = {k: NamedSharding(mesh, bspecs[k]) for k in batch_sds}
        dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)

        if cell.kind == "train":
            ocfg = opt_lib.OptConfig(moment_dtype=cfg.opt_dtype)
            aopt = opt_lib.abstract_state(ocfg, aparams)
            ospecs = opt_lib.state_specs(ocfg, pspecs)
            osh = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                               is_leaf=lambda x: isinstance(x, P))
            sched = opt_lib.warmup_cosine(3e-4, 100, 10_000)
            step = make_train_step(model, ocfg, sched, num_groups=dp,
                                   microbatch=microbatch)
            mesh_none = NamedSharding(mesh, P())
            out_sh = (psh, osh, {"loss": mesh_none, "grad_norm": mesh_none,
                                 "lr": mesh_none, "step": mesh_none})
            jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                             out_shardings=out_sh, donate_argnums=(0, 1))
            lowered = jitted.lower(aparams, aopt, batch_sds)
        elif cell.kind == "prefill":
            fn = make_prefill_step(model, num_groups=dp)
            csh = _named(mesh, sh.resolve_tree(model.cache_specs(), rules))
            logit_sh = NamedSharding(mesh, sh.resolve_spec(P("batch", "tp"), rules))
            jitted = jax.jit(fn, in_shardings=(psh, bsh),
                             out_shardings=(logit_sh, csh))
            lowered = jitted.lower(aparams, batch_sds)
        else:  # decode
            acache = model.abstract_cache(cell.global_batch, cell.seq_len)
            cspecs = sh.resolve_tree(model.cache_specs(), rules)
            csh = _named(mesh, cspecs)
            fn = make_decode_step(model)
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            logit_sh = NamedSharding(mesh, sh.resolve_spec(P("batch", "tp"), rules))
            jitted = jax.jit(fn, in_shardings=(psh, csh, bsh,
                                               NamedSharding(mesh, P())),
                             out_shardings=(logit_sh, csh),
                             donate_argnums=(1,))
            lowered = jitted.lower(aparams, acache, batch_sds, pos_sds)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    # ---- analyses ---------------------------------------------------------
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok", "num_devices": ndev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "overrides": overrides or {}, "rule_overrides": rule_overrides or {},
        "microbatch": microbatch,
        "num_params": model.num_params(),
    }
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        record["flops"] = float(cost.get("flops", 0.0))
        record["hlo_bytes"] = float(sum(v for k, v in cost.items()
                                        if k.startswith("bytes accessed")
                                        and k == "bytes accessed"))
        record["cost_raw"] = {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))}
    except Exception as e:                          # pragma: no cover
        record["cost_error"] = repr(e)
    try:
        mem = compiled.memory_analysis()
        record["memory_analysis"] = {
            a: int(getattr(mem, a)) for a in
            ("generated_code_size_in_bytes", "argument_size_in_bytes",
             "output_size_in_bytes", "temp_size_in_bytes", "alias_size_in_bytes")
            if hasattr(mem, a)}
    except Exception as e:                          # pragma: no cover
        record["memory_analysis_error"] = repr(e)

    # analytic per-device weight/optimizer/cache bytes (exact)
    wb = _sharded_bytes_per_device(aparams, pspecs, mesh)
    record["weight_bytes_per_device"] = wb
    if cell.kind == "train":
        record["opt_bytes_per_device"] = _sharded_bytes_per_device(
            jax.tree.leaves(aopt.mu) and aopt.mu or {}, ospecs.mu, mesh) + \
            _sharded_bytes_per_device(aopt.nu, ospecs.nu, mesh)
    if cell.kind == "decode":
        record["cache_bytes_per_device"] = _sharded_bytes_per_device(
            acache, cspecs, mesh)

    # Trip-count-aware HLO cost model (XLA's cost_analysis counts while
    # bodies once; see topology/hlocost.py).  All values are per-device.
    hlo = compiled.as_text()
    hc = hlocost.analyze(hlo, ndev)
    record["flops_hlo"] = hc.flops
    record["hbm_bytes"] = hc.hbm_bytes
    record["collective_bytes"] = hc.collective_bytes
    record["collectives"] = hc.by_collective
    record["hlo_size"] = len(hlo)
    return record


def cell_tag(rec: Dict[str, Any]) -> str:
    return f"{rec['arch']}.{rec['shape']}.{rec['mesh']}"


def run(arch_list, shape_list, meshes, overrides=None, rule_overrides=None,
        microbatch=1, out_dir=ARTIFACT_DIR, tag="") -> None:
    os.makedirs(out_dir, exist_ok=True)
    for arch in arch_list:
        for shape in shape_list:
            for multi in meshes:
                name = f"{arch}.{shape}.{'multi' if multi else 'single'}"
                if tag:
                    name += f".{tag}"
                path = os.path.join(out_dir, name + ".json")
                if os.path.exists(path):
                    print(f"== {name}: cached")
                    continue
                print(f"== {name}: lowering...", flush=True)
                try:
                    rec = lower_cell(arch, shape, multi, overrides,
                                     rule_overrides, microbatch)
                except Exception:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "error",
                           "traceback": traceback.format_exc()}
                rec["tag"] = tag
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec.get("status")
                print(f"   -> {status} "
                      f"(compile {rec.get('compile_s', '-')}s, "
                      f"flops {rec.get('flops', 0):.3g}, "
                      f"coll {rec.get('collective_bytes', 0):.3g}B)", flush=True)
                if status == "error":
                    print(rec["traceback"].splitlines()[-1])


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--override", action="append", default=[])
    ap.add_argument("--rule", action="append", default=[],
                    help="logical=physical sharding rule override, e.g. fsdp=data")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()

    archs = configs.ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = [c.name for c in LM_SHAPES] if args.shape == "all" \
        else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    rules = {}
    for r in args.rule:
        k, v = r.split("=", 1)
        rules[k] = tuple(v.split("+")) if v else None
    run(archs, shapes, meshes, _parse_overrides(args.override), rules or None,
        args.microbatch, args.out, args.tag)


if __name__ == "__main__":
    main()
