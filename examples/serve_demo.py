"""Batched serving demo: prefill + KV-cache decode with the slot engine.

    PYTHONPATH=src python examples/serve_demo.py
"""
import time

import numpy as np
import jax

from repro import configs
from repro.models.api import Model
from repro.serve.engine import Engine, ServeConfig


def main() -> None:
    cfg = configs.smoke_config("qwen3_4b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(max_new_tokens=24, temperature=0.8))

    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab_size, (4, 32)).astype(np.int32)
    t0 = time.time()
    out = eng.generate(prompts)
    dt = time.time() - t0
    print(f"batch=4, prompt=32, generated {out.shape[1]} tokens/request "
          f"in {dt:.2f}s")
    for i, row in enumerate(out):
        print(f"  request {i}: {row[:10].tolist()} ...")


if __name__ == "__main__":
    main()
