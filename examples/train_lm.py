"""End-to-end training driver: a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --quick    # CI-sized

Uses the full framework path: config -> mesh -> sharded train step ->
deterministic data pipeline -> async checkpointing with resume.  Kill it
mid-run and rerun: it resumes from the latest checkpoint.
"""
import argparse

from repro.models.config import ModelConfig
from repro.launch.train import train

CFG_100M = ModelConfig(
    name="lm-100m",
    num_layers=12, d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=32_768,
    layer_pattern="T" * 12,
    attn_q_chunk=128, attn_kv_chunk=256, loss_chunk=128,
)

CFG_QUICK = ModelConfig(
    name="lm-quick",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=2048,
    layer_pattern="T" * 4,
    attn_q_chunk=32, attn_kv_chunk=64, loss_chunk=32,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt_train_lm")
    args = ap.parse_args()

    cfg = CFG_QUICK if args.quick else CFG_100M
    steps = args.steps or (60 if args.quick else 300)
    seq = 64 if args.quick else 256
    batch = 4 if args.quick else 8

    from repro.models.api import Model
    n = Model(cfg).num_params()
    print(f"training {cfg.name}: {n/1e6:.1f}M params, {steps} steps, "
          f"seq {seq}, batch {batch}")
    out = train(cfg, steps=steps, global_batch=batch, seq_len=seq,
                lr=1e-3, warmup=20,
                checkpoint_dir=args.checkpoint_dir, checkpoint_every=50,
                log_every=10)
    h = out["history"]
    print(f"loss: first={h[0]['loss']:.3f} last={h[-1]['loss']:.3f}")
    assert h[-1]["loss"] < h[0]["loss"], "training did not descend"


if __name__ == "__main__":
    main()
