"""Resource-manager scenario from the paper: a stream of jobs arrives at a
supercomputer queue; for each job the manager allocates a subset of free
nodes and must map the job's process graph onto them within a timeout.

    PYTHONPATH=src python examples/job_mapping.py

Shows: PSA meets tight timeouts at every order (the paper's conclusion for
"regular jobs"), and the improvement of an optimised mapping over the naive
first-fit placement.
"""
import time

import numpy as np
import jax

from repro.core import annealing, instances, mapping, qap
from repro.topology import tpu


def main() -> None:
    rng = np.random.default_rng(0)
    # Machine: one v5e pod, 256 nodes.
    spec = tpu.PodSpec()
    m_full = tpu.distance_matrix(spec)
    free = np.ones(spec.num_chips, bool)

    jobs = [("job-a", 27), ("job-b", 75), ("job-c", 125), ("job-d", 45)]
    print(f"{'job':<8} {'nodes':>6} {'F naive':>12} {'F mapped':>12} "
          f"{'gain':>7} {'time':>7}")
    for name, n in jobs:
        # Allocate n free nodes (first-fit -- the unoptimised baseline).
        alloc = np.where(free)[0][:n]
        free[alloc] = False
        m = m_full[np.ix_(alloc, alloc)]
        # The job's information graph: a taiXe-style flow matrix.
        inst = instances.get_instance(n)
        c = inst.C

        t0 = time.time()
        res = mapping.find_mapping(
            c, m, "psa", key=jax.random.PRNGKey(n), num_processes=4,
            sa_cfg=annealing.SAConfig(max_neighbors=25, iters_per_exchange=25,
                                      num_exchanges=12, solvers=16))
        dt = time.time() - t0
        print(f"{name:<8} {n:>6} {res.baseline:>12.0f} {res.objective:>12.0f} "
              f"{res.improvement:>6.1%} {dt:>6.2f}s")
        free[alloc] = True   # job finishes (toy timeline)

    print("\nPSA fits the paper's resource-manager timeout for every order; "
          "the mapped placement cuts the modelled communication cost.")


if __name__ == "__main__":
    main()
