"""Quickstart: solve a job-mapping problem with the paper's three algorithms.

    PYTHONPATH=src python examples/quickstart.py

Generates a tai45-style instance (known optimum), runs parallel simulated
annealing / genetic / composite, and prints the paper's accuracy metric
A1 = 100*(F - F0)/F0 for each.
"""
import time

import jax

from repro.core import annealing, genetic, instances, mapping, qap


def main() -> None:
    inst = instances.get_instance(45)
    print(f"instance {inst.name}: n={inst.n}, known optimum F0={inst.optimum:.0f}")

    sa_cfg = annealing.SAConfig(max_neighbors=30, iters_per_exchange=40,
                                num_exchanges=15, solvers=16)
    ga_cfg = genetic.GAConfig(generations=120)

    print(f"{'algorithm':<12} {'F':>10} {'A1':>8} {'time':>8}")
    for algo in ("psa", "pga", "pca", "identity"):
        res = mapping.find_mapping(inst.C, inst.M, algo,
                                   key=jax.random.PRNGKey(0), num_processes=4,
                                   sa_cfg=sa_cfg, ga_cfg=ga_cfg)
        a1 = 100 * (res.objective - inst.optimum) / inst.optimum
        print(f"{algo:<12} {res.objective:>10.0f} {a1:>7.1f}% "
              f"{res.seconds:>7.2f}s")
    print("\n(identity = unoptimised placement; the paper's Table 1 compares "
          "the three parallel algorithms on instances of order 27..729)")


if __name__ == "__main__":
    main()
